//! EB_BIT — edge-based speculative coloring (Deveci et al.).
//!
//! The assignment pass is the same bit-window greedy as VB_BIT, but the
//! conflict pass is *edge-parallel*: one unit of work per edge rather
//! than per vertex, which balances load on skewed-degree graphs (the
//! reason the paper's heuristic picks EB_BIT when δ_max > 6000).  Both
//! passes read a snapshot and stage their writes (Jacobi semantics), so
//! the worklist chunks fan out across the worker threads with no
//! synchronization and a thread-count-independent result.

use crate::coloring::local::{KernelScratch, LocalView};
use crate::coloring::Color;
use crate::graph::VId;
use crate::util::bitset::BitSet;

/// Color the masked vertices of `view` to fixpoint, serially.
/// Returns #rounds.
pub fn color(view: &LocalView, colors: &mut [Color]) -> usize {
    color_with(view, colors, &mut KernelScratch::new(1))
}

/// [`color`] over `threads` workers (0 = auto); bit-identical to serial.
pub fn color_par(view: &LocalView, colors: &mut [Color], threads: usize) -> usize {
    color_with(view, colors, &mut KernelScratch::new(threads))
}

/// Full-control entry: thread knob and priority cache from `scratch`.
pub fn color_with(view: &LocalView, colors: &mut [Color], scratch: &mut KernelScratch) -> usize {
    let g = view.graph;
    let n = g.n();
    debug_assert_eq!(colors.len(), n);
    debug_assert_eq!(view.mask.len(), n);

    let exec = scratch.executor();
    let prio = scratch.prio32(n);
    let mut work: Vec<VId> = (0..n as VId)
        .filter(|&v| view.mask[v as usize] && colors[v as usize] == 0)
        .collect();
    let mut in_work = vec![false; n];
    let mut rounds = 0usize;

    while !work.is_empty() {
        rounds += 1;
        // assignment pass (identical to VB_BIT): snapshot + staged writes
        let staged: Vec<(VId, Color)> = {
            let snapshot: &[Color] = colors;
            exec.flat_map_chunks(&work, |chunk| {
                let mut forbidden = BitSet::with_capacity(64);
                let mut out: Vec<(VId, Color)> = Vec::with_capacity(chunk.len());
                for &v in chunk {
                    forbidden.clear();
                    for u in g.neighbors(v) {
                        let c = snapshot[u as usize];
                        if c > 0 {
                            forbidden.set(c as usize - 1);
                        }
                    }
                    out.push((v, forbidden.first_zero() as Color + 1));
                }
                out
            })
        };
        for &(v, c) in &staged {
            colors[v as usize] = c;
            in_work[v as usize] = true;
        }
        // edge-parallel conflict detection over a snapshot: one unit of
        // work per arc of a worked vertex; stage the loser of every
        // conflict edge.  A conflict only arises between two same-round
        // assignments (assignment forbids all snapshot colors), so the
        // loser is always in-work; the check keeps that invariant hot.
        let mut uncolor: Vec<VId> = {
            let snapshot: &[Color] = colors;
            let in_work: &[bool] = &in_work;
            exec.flat_map_chunks(&work, |chunk| {
                let mut out: Vec<VId> = Vec::new();
                for &v in chunk {
                    let cv = snapshot[v as usize];
                    for u in g.neighbors(v) {
                        if snapshot[u as usize] == cv {
                            // conflict edge (v, u): hashed-priority loser
                            let loser =
                                if (prio[u as usize], u) < (prio[v as usize], v) { v } else { u };
                            if in_work[loser as usize] {
                                out.push(loser);
                            }
                        }
                    }
                }
                out
            })
        };
        for &v in &work {
            in_work[v as usize] = false;
        }
        uncolor.sort_unstable();
        uncolor.dedup();
        for &v in &uncolor {
            colors[v as usize] = 0;
        }
        work = uncolor;
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::local::LocalView;
    use crate::coloring::validate::is_proper_d1;
    use crate::coloring::max_color;
    use crate::graph::generators::{ba, erdos_renyi::gnm};
    use crate::graph::Graph;

    fn run_all(g: &Graph) -> Vec<Color> {
        let mask = vec![true; g.n()];
        let mut colors = vec![0; g.n()];
        color(&LocalView { graph: g, mask: &mask }, &mut colors);
        colors
    }

    #[test]
    fn proper_on_random_graphs() {
        for seed in 0..5 {
            let g = gnm(300, 2000, seed);
            let c = run_all(&g);
            assert!(is_proper_d1(&g, &c), "seed {seed}");
            assert!(max_color(&c) as usize <= g.max_degree() + 1);
        }
    }

    #[test]
    fn proper_on_heavy_tail() {
        // the workload class EB_BIT exists for
        let g = ba::preferential_attachment(2000, 6, 3);
        let c = run_all(&g);
        assert!(is_proper_d1(&g, &c));
    }

    #[test]
    fn matches_vb_bit_properness_not_necessarily_colors() {
        let g = gnm(200, 1000, 9);
        let eb = run_all(&g);
        let mask = vec![true; g.n()];
        let mut vb = vec![0; g.n()];
        super::super::vb_bit::color(&LocalView { graph: &g, mask: &mask }, &mut vb);
        assert!(is_proper_d1(&g, &eb));
        assert!(is_proper_d1(&g, &vb));
    }
}
