//! Graph coloring: local ("on-GPU") kernels, the distributed speculative
//! framework, and validation.
//!
//! Color `0` is "uncolored" everywhere (as in the paper: "our coloring
//! functions interpret color zero as uncolored"); proper colors are
//! 1-based `u32`s.

pub mod distributed;
pub mod local;
pub mod validate;

/// A vertex color; 0 = uncolored.
pub type Color = u32;

/// Which coloring problem to solve (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Problem {
    /// Distance-1: adjacent vertices differ.
    D1,
    /// Distance-2: vertices within two hops differ.
    D2,
    /// Partial distance-2: only two-hop conflicts matter (bipartite use).
    PD2,
}

impl std::fmt::Display for Problem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Problem::D1 => write!(f, "D1"),
            Problem::D2 => write!(f, "D2"),
            Problem::PD2 => write!(f, "PD2"),
        }
    }
}

/// Number of distinct colors used (ignoring uncolored).
// membership-only set: only its len() is observed, never its order
#[allow(clippy::disallowed_types)]
pub fn colors_used(colors: &[Color]) -> usize {
    let mut seen = std::collections::HashSet::new();
    for &c in colors {
        if c > 0 {
            seen.insert(c);
        }
    }
    seen.len()
}

/// Largest color value used (the paper reports "number of colors", which
/// for first-fit greedy equals the max since colors are dense from 1).
pub fn max_color(colors: &[Color]) -> Color {
    colors.iter().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colors_used_ignores_uncolored() {
        assert_eq!(colors_used(&[0, 1, 2, 2, 0]), 2);
        assert_eq!(colors_used(&[]), 0);
    }

    #[test]
    fn max_color_of_empty_is_zero() {
        assert_eq!(max_color(&[]), 0);
        assert_eq!(max_color(&[3, 1]), 3);
    }
}
