//! Proper-coloring validation for all three problem variants.
//!
//! These are the ground-truth checkers every algorithm and every
//! distributed configuration is tested against.

use crate::coloring::{Color, Problem};
use crate::graph::{BipartiteGraph, Graph, VId};

/// Distance-1 proper: all vertices colored, no monochromatic edge.
pub fn is_proper_d1(g: &Graph, colors: &[Color]) -> bool {
    first_violation_d1(g, colors).is_none()
}

/// First D1 violation (for diagnostics): vertex pair or uncolored vertex.
pub fn first_violation_d1(g: &Graph, colors: &[Color]) -> Option<(VId, VId)> {
    debug_assert_eq!(colors.len(), g.n());
    for v in 0..g.n() as VId {
        if colors[v as usize] == 0 {
            return Some((v, v));
        }
        for u in g.neighbors(v) {
            if u > v && colors[u as usize] == colors[v as usize] {
                return Some((v, u));
            }
        }
    }
    None
}

/// Distance-2 proper: D1 proper and no two vertices at distance exactly 2
/// share a color.
pub fn is_proper_d2(g: &Graph, colors: &[Color]) -> bool {
    if !is_proper_d1(g, colors) {
        return false;
    }
    no_two_hop_conflicts(g, colors, None)
}

/// Partial distance-2 proper over a general graph: every vertex colored,
/// no *two-hop* conflict (distance-1 conflicts are allowed).
pub fn is_proper_pd2(g: &Graph, colors: &[Color]) -> bool {
    if colors.iter().take(g.n()).any(|&c| c == 0) {
        return false;
    }
    no_two_hop_conflicts(g, colors, None)
}

/// Partial distance-2 proper on a bipartite graph, checking only the
/// source side `V_s` (the set applications color, §3.6).
pub fn is_proper_pd2_source_side(bg: &BipartiteGraph, colors: &[Color]) -> bool {
    let g = &bg.graph;
    for v in 0..bg.ns as VId {
        if colors[v as usize] == 0 {
            return false;
        }
    }
    no_two_hop_conflicts(g, colors, Some(bg.ns))
}

/// Check that no two distinct vertices (below `limit` if given) at
/// distance two share a color, via the net formulation: all pairs of
/// neighbors of any vertex are two-hop pairs.
// lookup-only map: queried per neighbor, never iterated, so bucket
// order cannot reach the boolean verdict
#[allow(clippy::disallowed_types)]
fn no_two_hop_conflicts(g: &Graph, colors: &[Color], limit: Option<usize>) -> bool {
    let lim = limit.unwrap_or(g.n());
    let mut seen: std::collections::HashMap<Color, VId> = std::collections::HashMap::new();
    for u in 0..g.n() as VId {
        seen.clear();
        for v in g.neighbors(u) {
            if (v as usize) >= lim {
                continue;
            }
            let c = colors[v as usize];
            if c == 0 {
                continue;
            }
            if let Some(&w) = seen.get(&c) {
                if w != v {
                    return false;
                }
            } else {
                seen.insert(c, v);
            }
        }
    }
    true
}

/// Validate against the right checker for `problem`.
pub fn is_proper(problem: Problem, g: &Graph, colors: &[Color]) -> bool {
    match problem {
        Problem::D1 => is_proper_d1(g, colors),
        Problem::D2 => is_proper_d2(g, colors),
        Problem::PD2 => is_proper_pd2(g, colors),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path3() -> Graph {
        GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build()
    }

    #[test]
    fn d1_accepts_and_rejects() {
        let g = path3();
        assert!(is_proper_d1(&g, &[1, 2, 1]));
        assert!(!is_proper_d1(&g, &[1, 1, 2]));
        assert!(!is_proper_d1(&g, &[1, 0, 2])); // uncolored
    }

    #[test]
    fn d2_requires_endpoint_distinct() {
        let g = path3();
        // 0 and 2 are two hops apart through 1
        assert!(!is_proper_d2(&g, &[1, 2, 1]));
        assert!(is_proper_d2(&g, &[1, 2, 3]));
    }

    #[test]
    fn pd2_allows_adjacent_same_color() {
        let g = path3();
        // distance-1 conflict 1-2 allowed in partial coloring; two-hop 0-2 not
        assert!(is_proper_pd2(&g, &[1, 1, 2]));
        assert!(!is_proper_pd2(&g, &[1, 2, 1]));
    }

    #[test]
    fn pd2_source_side_ignores_target_side() {
        // bipartite: sources {0,1}, target {2}; 0-2, 1-2 edges
        let g = GraphBuilder::new(3).edges(&[(0, 2), (1, 2)]).build();
        let bg = BipartiteGraph { graph: g, ns: 2 };
        // sources share target => must differ; target color irrelevant (0 ok)
        assert!(is_proper_pd2_source_side(&bg, &[1, 2, 0]));
        assert!(!is_proper_pd2_source_side(&bg, &[1, 1, 0]));
    }

    #[test]
    fn violation_reports_uncolored_vertex() {
        let g = path3();
        assert_eq!(first_violation_d1(&g, &[1, 0, 1]), Some((1, 1)));
    }
}
