//! repolint — run the in-tree invariant linter over the repository.
//!
//! Usage:
//!   repolint [--json] [--root <dir>]
//!
//! Exits 0 when the tree is clean, 1 when there are findings, 2 on
//! usage or I/O errors.  `scripts/verify.sh` runs this as a hard gate
//! ahead of the test suite; see `docs/LINTS.md` for the rule catalog
//! and the allow-annotation escape hatch.

use dist_color::lint;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("repolint: --root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                println!("usage: repolint [--json] [--root <dir>]");
                println!("lints the repo against the invariant catalog in docs/LINTS.md;");
                println!("exit 0 = clean, 1 = findings, 2 = error");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("repolint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    // default root: the cwd when it looks like the package root (the
    // verify.sh path), else the compile-time manifest dir
    let root = root.unwrap_or_else(|| {
        if PathBuf::from("Cargo.toml").is_file() {
            PathBuf::from(".")
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        }
    });
    match lint::run_repo(&root) {
        Err(e) => {
            eprintln!("repolint: {e}");
            ExitCode::from(2)
        }
        Ok(findings) => {
            if json {
                println!("{}", lint::render_json(&findings));
            } else {
                for f in &findings {
                    println!("{f}");
                }
            }
            if findings.is_empty() {
                if !json {
                    eprintln!("repolint: clean");
                }
                ExitCode::SUCCESS
            } else {
                if !json {
                    eprintln!("repolint: {} finding(s)", findings.len());
                }
                ExitCode::FAILURE
            }
        }
    }
}
