//! Compact bitsets for forbidden-color tracking — the Rust twin of the
//! bit-based color windows in KokkosKernels' VB_BIT / EB_BIT kernels.

/// A growable bitset over `u64` words with a "find first zero" primitive.
#[derive(Clone, Debug, Default)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    pub fn with_capacity(bits: usize) -> Self {
        BitSet { words: vec![0; bits.div_ceil(64)] }
    }

    /// Clear all bits, keeping capacity (hot-loop friendly).
    #[inline]
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        let w = i / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        let w = i / 64;
        w < self.words.len() && (self.words[w] >> (i % 64)) & 1 == 1
    }

    /// Index of the lowest zero bit (grows conceptually without bound).
    #[inline]
    pub fn first_zero(&self) -> usize {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != u64::MAX {
                return wi * 64 + w.trailing_ones() as usize;
            }
        }
        self.words.len() * 64
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = BitSet::with_capacity(10);
        b.set(3);
        b.set(200); // forces growth
        assert!(b.get(3));
        assert!(b.get(200));
        assert!(!b.get(4));
        assert!(!b.get(1000));
    }

    #[test]
    fn first_zero_skips_set_prefix() {
        let mut b = BitSet::with_capacity(130);
        for i in 0..130 {
            b.set(i);
        }
        assert_eq!(b.first_zero(), 130);
        let empty = BitSet::with_capacity(64);
        assert_eq!(empty.first_zero(), 0);
    }

    #[test]
    fn first_zero_finds_hole() {
        let mut b = BitSet::with_capacity(8);
        b.set(0);
        b.set(1);
        b.set(3);
        assert_eq!(b.first_zero(), 2);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut b = BitSet::with_capacity(256);
        b.set(255);
        b.clear();
        assert_eq!(b.count_ones(), 0);
        assert_eq!(b.first_zero(), 0);
    }
}
