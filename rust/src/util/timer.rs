//! Split timers for the comm/comp breakdowns of Figures 4, 9 and 12.
//!
//! Computation is measured in **per-thread CPU time**
//! (`CLOCK_THREAD_CPUTIME_ID`), not wall clock: simulated ranks are OS
//! threads and typically oversubscribe the host's cores, so wall time
//! would measure the scheduler, not the algorithm.  Thread CPU time is
//! exactly the "one processor per rank" semantics the simulation needs —
//! each rank's comp time is what it would cost on a dedicated core.
//! Communication keeps wall time (blocked receives consume no CPU) plus
//! the α–β modeled time accounted by [`crate::distributed::cost`].

use std::time::Duration;

/// Current thread CPU time.
pub fn thread_cpu_now() -> Duration {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Accumulates computation (thread CPU) and communication (wall) time.
#[derive(Clone, Debug, Default)]
pub struct SplitTimer {
    pub comp: Duration,
    pub comm: Duration,
}

impl SplitTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, attributing its *thread CPU time* to computation.
    pub fn comp<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = thread_cpu_now();
        let out = f();
        self.comp += thread_cpu_now().saturating_sub(t);
        out
    }

    /// Time `f`, attributing its *wall time* to communication.
    pub fn comm<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = std::time::Instant::now();
        let out = f();
        self.comm += t.elapsed();
        out
    }

    pub fn total(&self) -> Duration {
        self.comp + self.comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_accumulate() {
        let mut t = SplitTimer::new();
        let x = t.comp(|| 21 * 2);
        assert_eq!(x, 42);
        t.comm(|| std::thread::sleep(Duration::from_millis(1)));
        assert!(t.comm >= Duration::from_millis(1));
        assert_eq!(t.total(), t.comp + t.comm);
    }

    #[test]
    fn thread_cpu_time_advances_under_load() {
        let t0 = thread_cpu_now();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i.wrapping_mul(2654435761));
        }
        std::hint::black_box(acc);
        assert!(thread_cpu_now() > t0);
    }

    #[test]
    fn sleep_does_not_charge_cpu_time() {
        let mut t = SplitTimer::new();
        t.comp(|| std::thread::sleep(Duration::from_millis(5)));
        // sleeping burns (almost) no CPU time
        assert!(t.comp < Duration::from_millis(3), "comp={:?}", t.comp);
    }
}
