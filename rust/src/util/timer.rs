//! Split timers for the comm/comp breakdowns of Figures 4, 9 and 12.
//!
//! Computation is measured in **per-thread CPU time**
//! (`CLOCK_THREAD_CPUTIME_ID`), not wall clock: simulated ranks are OS
//! threads and typically oversubscribe the host's cores, so wall time
//! would measure the scheduler, not the algorithm.  Thread CPU time is
//! exactly the "one processor per rank" semantics the simulation needs —
//! each rank's comp time is what it would cost on a dedicated core.
//! Communication keeps wall time (blocked receives consume no CPU) plus
//! the α–β modeled time accounted by [`crate::distributed::cost`].

use std::time::Duration;

// The repo carries no external crates, so the thread-CPU clock is read
// through a direct `clock_gettime` declaration instead of the `libc`
// crate (libc itself is always linked via std on our targets).  The
// i64/i64 timespec layout only matches the kernel ABI on 64-bit Linux
// (32-bit targets use 32-bit time_t/long), so the declaration is gated
// on pointer width and everything else takes the wall-clock fallback.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
mod sys {
    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }
    /// Linux's CLOCK_THREAD_CPUTIME_ID (uapi/linux/time.h).
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    extern "C" {
        pub fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
}

/// Current thread CPU time.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn thread_cpu_now() -> Duration {
    let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    let rc = unsafe { sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Fallback for non-Linux / 32-bit targets: monotonic wall time
/// (oversubscribed rank threads will overcount comp, but the crate
/// still builds and runs).
#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn thread_cpu_now() -> Duration {
    use std::sync::OnceLock;
    use std::time::Instant;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed()
}

/// Accumulates computation (thread CPU) and communication (wall) time.
#[derive(Clone, Debug, Default)]
pub struct SplitTimer {
    pub comp: Duration,
    pub comm: Duration,
}

impl SplitTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, attributing its *thread CPU time* to computation —
    /// including CPU burned by `util::par` worker threads spawned on
    /// this thread's behalf, which the thread clock alone cannot see.
    pub fn comp<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = thread_cpu_now();
        let w0 = crate::util::par::worker_cpu_ns();
        let out = f();
        let workers = crate::util::par::worker_cpu_ns().saturating_sub(w0);
        self.comp += thread_cpu_now().saturating_sub(t) + Duration::from_nanos(workers);
        out
    }

    /// Time `f`, attributing its *wall time* to communication.
    pub fn comm<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = std::time::Instant::now();
        let out = f();
        self.comm += t.elapsed();
        out
    }

    /// Attribute the wall time since `since` to communication.  The
    /// `async` comm call sites cannot wrap an `.await` in the [`comm`]
    /// closure (closures can't await), so they bracket the await with
    /// `let t0 = Instant::now(); ... .await?; timers.comm_add(t0);`.
    /// Under the cooperative scheduler this measures submit-to-complete
    /// wall time — the same quantity the blocking wrapper observed —
    /// regardless of which worker thread resumes the rank.
    ///
    /// [`comm`]: SplitTimer::comm
    pub fn comm_add(&mut self, since: std::time::Instant) {
        self.comm += since.elapsed();
    }

    pub fn total(&self) -> Duration {
        self.comp + self.comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_accumulate() {
        let mut t = SplitTimer::new();
        let x = t.comp(|| 21 * 2);
        assert_eq!(x, 42);
        t.comm(|| std::thread::sleep(Duration::from_millis(1)));
        assert!(t.comm >= Duration::from_millis(1));
        assert_eq!(t.total(), t.comp + t.comm);
    }

    #[test]
    fn thread_cpu_time_advances_under_load() {
        let t0 = thread_cpu_now();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i.wrapping_mul(2654435761));
        }
        std::hint::black_box(acc);
        assert!(thread_cpu_now() > t0);
    }

    #[test]
    fn sleep_does_not_charge_cpu_time() {
        let mut t = SplitTimer::new();
        t.comp(|| std::thread::sleep(Duration::from_millis(5)));
        // sleeping burns (almost) no CPU time
        assert!(t.comp < Duration::from_millis(3), "comp={:?}", t.comp);
    }
}
