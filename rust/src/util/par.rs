//! Shared-memory parallel execution layer for the on-node kernels.
//!
//! The paper's on-node coloring is Deveci et al.'s bit-based kernels
//! running data-parallel over the worklist; this module is the Rust twin
//! of that execution model: a chunked map with no external dependencies.
//! Two execution strategies share one contract:
//!
//! * [`map_chunks`] / [`flat_map_chunks`] — scoped threads spawned per
//!   call (`std::thread::scope`, the idiom of the rank runtime in
//!   `distributed/comm.rs`).  Simple, but a spawn is ~10µs, which
//!   dominates on the small loser worklists of the speculative fix loop.
//! * [`WorkerPool`] / [`Executor`] — a persistent pool whose workers
//!   park on a condvar between jobs; waking them costs ~1µs.  Each rank
//!   owns one pool through `KernelScratch`, and every kernel pass and
//!   conflict-detection scan of a round reuses it.
//!
//! Determinism contract (both strategies): the input splits into
//! contiguous in-order chunks and per-chunk results are returned **in
//! chunk order**, so any algorithm whose chunk function is a pure map
//! over a snapshot (the Jacobi formulation of VB_BIT/EB_BIT/NB_BIT)
//! produces output that is bit-identical for every thread count —
//! asserted by `rust/tests/parallel_kernels.rs`.

// clippy.toml bans thread spawns repo-wide; this module IS the
// sanctioned executor every other spawn must route through.
#![allow(clippy::disallowed_methods)]

use std::cell::Cell;
use std::fmt;
use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::timer::thread_cpu_now;

/// Below this many items per worker, fan-out costs more than it saves
/// (thread spawn is ~10µs; a worklist item is ~100ns): run serially.
/// Chunk boundaries never affect results, so this is safe to tune.
const MIN_ITEMS_PER_THREAD: usize = 512;

/// The pooled analogue of [`MIN_ITEMS_PER_THREAD`]: a condvar wake is
/// ~1µs, so pooled fan-out pays off on much smaller worklists.
const MIN_ITEMS_PER_POOL_WORKER: usize = 64;

thread_local! {
    /// CPU nanoseconds burned by this thread's *workers* in `map_chunks`
    /// fan-outs (monotone counter).  `SplitTimer::comp` measures the
    /// calling thread's CPU clock, which cannot see worker threads;
    /// crediting worker CPU here keeps per-rank comp accounting honest
    /// when the kernels run with threads > 1.
    static WORKER_CPU_NS: Cell<u64> = const { Cell::new(0) };

    /// True while this thread is executing a pool chunk.  Submitting a
    /// nested job to the pool from inside a chunk would deadlock it (the
    /// inner `run` would wait on a slot the outer job can never release
    /// because this thread still owes its chunk), so [`Executor`] checks
    /// this flag and degrades nested maps to the inline serial path.
    static IN_POOL_CHUNK: Cell<bool> = const { Cell::new(false) };
}

/// Monotone per-thread counter of worker CPU time (ns) spent on this
/// thread's behalf.  Read before/after a computation and add the delta
/// to the calling thread's own CPU clock for total attributed comp.
pub fn worker_cpu_ns() -> u64 {
    WORKER_CPU_NS.with(|c| c.get())
}

fn credit_worker_cpu(ns: u64) {
    WORKER_CPU_NS.with(|c| c.set(c.get() + ns));
}

/// Run one claimed chunk with the re-entrancy flag raised.
fn run_chunk_guarded(task: &(dyn Fn(usize) + Sync), i: usize) {
    IN_POOL_CHUNK.with(|c| c.set(true));
    task(i);
    IN_POOL_CHUNK.with(|c| c.set(false));
}

/// Resolve a thread-count knob: `0` means one worker per available core.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Workers actually worth launching for `len` items.
fn effective_threads(threads: usize, len: usize) -> usize {
    resolve_threads(threads).min(len / MIN_ITEMS_PER_THREAD).max(1)
}

/// Split `0..len` into `k` contiguous, balanced, in-order ranges.
pub fn chunk_ranges(len: usize, k: usize) -> Vec<Range<usize>> {
    let k = k.max(1).min(len.max(1));
    let base = len / k;
    let rem = len % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let sz = base + usize::from(i < rem);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// Apply `f` to contiguous chunks of `items` on up to `threads` scoped
/// workers; results are returned in chunk (= input) order.  `threads`
/// of 0 means auto; 1 (or a small input) degenerates to a single
/// in-thread call with no spawning.
pub fn map_chunks<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&[T]) -> R + Sync,
) -> Vec<R> {
    let k = effective_threads(threads, items.len());
    if k <= 1 {
        return vec![f(items)];
    }
    let ranges = chunk_ranges(items.len(), k);
    let f = &f;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len() - 1);
        for r in &ranges[1..] {
            let chunk = &items[r.clone()];
            // each worker reports its own CPU time so the caller can
            // attribute it (the caller's CPU clock cannot see workers)
            handles.push(scope.spawn(move || {
                let t0 = thread_cpu_now();
                let out = f(chunk);
                (out, thread_cpu_now().saturating_sub(t0))
            }));
        }
        // chunk 0 runs on the calling thread while the workers spin
        let mut out = Vec::with_capacity(ranges.len());
        out.push(f(&items[ranges[0].clone()]));
        let mut foreign_ns = 0u64;
        for h in handles {
            let (r, cpu) = h.join().expect("parallel worker panicked");
            foreign_ns += cpu.as_nanos() as u64;
            out.push(r);
        }
        credit_worker_cpu(foreign_ns);
        out
    })
}

/// [`map_chunks`] flattened: concatenate the per-chunk `Vec`s in chunk
/// order.  The common shape of the kernels' staged-write passes.
pub fn flat_map_chunks<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&[T]) -> Vec<R> + Sync,
) -> Vec<R> {
    concat_parts(map_chunks(threads, items, f))
}

// ---------------------------------------------------------------------
// persistent worker pool
// ---------------------------------------------------------------------

/// Lifetime-erased job closure: `f(chunk_index)`.  The pointee outlives
/// the job because [`PoolCore::run`] clears the slot and returns only
/// after every chunk has finished.
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (safe to call from any thread) and the
// run protocol guarantees it is never dereferenced after `run` returns.
unsafe impl Send for TaskPtr {}

struct PoolState {
    /// Current job, if any.
    task: Option<TaskPtr>,
    /// Bumped per job so a worker never mixes chunks of two jobs.
    epoch: u64,
    nchunks: usize,
    /// Next unclaimed chunk index.
    next: usize,
    /// Chunks completed (job done when `finished == nchunks`).
    finished: usize,
    /// CPU ns burned by pool workers on the current job.
    worker_ns: u64,
    shutdown: bool,
}

struct PoolCore {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitter parks here until `finished == nchunks`.
    done: Condvar,
}

impl PoolCore {
    /// Execute `task(0..nchunks)` across the pool.  The calling thread
    /// claims chunks too, so the job completes even with zero live
    /// workers.  Returns worker (not caller) CPU ns spent on the job.
    fn run(&self, nchunks: usize, task: &(dyn Fn(usize) + Sync)) -> u64 {
        let mut st = self.state.lock().unwrap();
        // shared Executor handles could in principle race on the slot;
        // serialize submitters rather than corrupt a job
        while st.task.is_some() {
            st = self.done.wait(st).unwrap();
        }
        st.epoch += 1;
        st.task = Some(TaskPtr(task as *const _));
        st.nchunks = nchunks;
        st.next = 0;
        st.finished = 0;
        st.worker_ns = 0;
        drop(st);
        self.work.notify_all();
        let mut st = self.state.lock().unwrap();
        while st.next < st.nchunks {
            let i = st.next;
            st.next += 1;
            drop(st);
            run_chunk_guarded(task, i);
            st = self.state.lock().unwrap();
            st.finished += 1;
        }
        while st.finished < st.nchunks {
            st = self.done.wait(st).unwrap();
        }
        let ns = st.worker_ns;
        st.task = None;
        drop(st);
        self.done.notify_all();
        ns
    }

    fn worker_loop(&self) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return;
            }
            if st.task.is_some() && st.next < st.nchunks {
                let task = st.task.as_ref().unwrap().0;
                let epoch = st.epoch;
                while st.task.is_some() && st.epoch == epoch && st.next < st.nchunks {
                    let i = st.next;
                    st.next += 1;
                    drop(st);
                    let t0 = thread_cpu_now();
                    // SAFETY: a chunk was claimed under the lock, so the
                    // job (and its closure) cannot complete before this
                    // chunk's `finished` increment below.
                    let task_ref: &(dyn Fn(usize) + Sync) = unsafe { &*task };
                    run_chunk_guarded(task_ref, i);
                    let dt = thread_cpu_now().saturating_sub(t0);
                    st = self.state.lock().unwrap();
                    st.worker_ns += dt.as_nanos() as u64;
                    st.finished += 1;
                    if st.finished == st.nchunks {
                        self.done.notify_all();
                    }
                }
            } else {
                st = self.work.wait(st).unwrap();
            }
        }
    }
}

/// A persistent chunk-executing thread pool: `threads - 1` workers
/// parked on a condvar (the submitting thread is the last worker).
/// Owned by a rank's `KernelScratch`; kernels and detection passes grab
/// cheap [`Executor`] handles via [`WorkerPool::executor`].
pub struct WorkerPool {
    threads: usize,
    core: Arc<PoolCore>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool sized for `threads` total workers (0 = one per core).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = resolve_threads(threads);
        let core = Arc::new(PoolCore {
            state: Mutex::new(PoolState {
                task: None,
                epoch: 0,
                nchunks: 0,
                next: 0,
                finished: 0,
                worker_ns: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("par-pool-{i}"))
                    .spawn(move || core.worker_loop())
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { threads, core, handles }
    }

    /// Total workers (including the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// A cheap, clonable handle for running chunked maps on this pool.
    pub fn executor(&self) -> Executor {
        Executor { threads: self.threads, core: Some(Arc::clone(&self.core)) }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.core.state.lock().unwrap().shutdown = true;
        self.core.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

/// Handle for chunked maps: pooled when built from a [`WorkerPool`],
/// serial otherwise.  Same in-order chunk contract as [`map_chunks`].
#[derive(Clone)]
pub struct Executor {
    threads: usize,
    core: Option<Arc<PoolCore>>,
}

impl Executor {
    /// An executor that runs everything on the calling thread.
    pub fn serial() -> Executor {
        Executor { threads: 1, core: None }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// [`map_chunks`] over an index range with no backing slice: `f`
    /// receives contiguous in-order sub-ranges of `0..len`; results come
    /// back in chunk order.
    pub fn map_range_chunks<R: Send>(
        &self,
        len: usize,
        f: impl Fn(Range<usize>) -> R + Sync,
    ) -> Vec<R> {
        // nested submission from inside a pool chunk would deadlock the
        // pool — run such (and serial / small) maps inline instead
        let nested = IN_POOL_CHUNK.with(|c| c.get());
        let k = match &self.core {
            Some(_) if !nested => self.threads.min(len / MIN_ITEMS_PER_POOL_WORKER).max(1),
            _ => 1,
        };
        if k <= 1 {
            return vec![f(0..len)];
        }
        let core = self.core.as_ref().unwrap();
        let ranges = chunk_ranges(len, k);
        let slots: Vec<Mutex<Option<R>>> = (0..k).map(|_| Mutex::new(None)).collect();
        let worker_ns = core.run(k, &|i| {
            let r = f(ranges[i].clone());
            *slots[i].lock().unwrap() = Some(r);
        });
        credit_worker_cpu(worker_ns);
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("pool chunk not run"))
            .collect()
    }

    /// Pooled twin of [`map_chunks`] (same determinism contract).
    pub fn map_chunks<T: Sync, R: Send>(
        &self,
        items: &[T],
        f: impl Fn(&[T]) -> R + Sync,
    ) -> Vec<R> {
        self.map_range_chunks(items.len(), |r| f(&items[r]))
    }

    /// Pooled twin of [`flat_map_chunks`].
    pub fn flat_map_chunks<T: Sync, R: Send>(
        &self,
        items: &[T],
        f: impl Fn(&[T]) -> Vec<R> + Sync,
    ) -> Vec<R> {
        concat_parts(self.map_chunks(items, f))
    }
}

impl fmt::Debug for Executor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Executor")
            .field("threads", &self.threads)
            .field("pooled", &self.core.is_some())
            .finish()
    }
}

/// Concatenate per-chunk vectors in chunk order (no re-copy when there
/// is only one chunk — the serial path).
fn concat_parts<R>(parts: Vec<Vec<R>>) -> Vec<R> {
    match <[_; 1]>::try_from(parts) {
        Ok([only]) => only,
        Err(parts) => {
            let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
            for mut p in parts {
                out.append(&mut p);
            }
            out
        }
    }
}

// ---------------------------------------------------------------------
// cooperative task driver (the rank runtime's scheduler)
// ---------------------------------------------------------------------
//
// The distributed layer models each rank as a future whose yield points
// are exactly the blocking `Comm` operations.  [`drive_tasks`] runs M
// such rank state machines on N condvar-parked workers — the same
// parked-worker idiom as [`WorkerPool`], but scheduling *suspendable*
// tasks instead of run-to-completion chunks, so thousands of modeled
// ranks share a fixed thread budget instead of one OS thread each.

use std::collections::VecDeque;
use std::future::Future;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::pin::Pin;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::task::{Context, Poll, Wake, Waker};

/// A boxed, pinned task future; `'a` lets rank bodies borrow the plan
/// and session they run against (the driver joins every worker before
/// returning, so no task outlives the borrow).
pub type BoxFuture<'a, T> = Pin<Box<dyn Future<Output = T> + Send + 'a>>;

/// Live scheduler workers across all concurrent [`drive_tasks`] calls,
/// and the high-water mark since the last [`reset_sched_worker_peak`].
/// This is the "no per-rank OS threads" witness: the peak tracks the
/// worker *budget*, not the modeled rank count (`BENCH_PR7` pins it
/// flat from p=64 to p=1024).
static SCHED_WORKERS_LIVE: AtomicUsize = AtomicUsize::new(0);
static SCHED_WORKERS_PEAK: AtomicUsize = AtomicUsize::new(0);

/// Peak concurrent scheduler workers since the last reset.
pub fn sched_worker_peak() -> usize {
    SCHED_WORKERS_PEAK.load(Ordering::Relaxed)
}

/// Reset the peak-worker gauge (bench instrumentation; racy across
/// concurrent drivers, so only meaningful on a quiet process).
pub fn reset_sched_worker_peak() {
    SCHED_WORKERS_PEAK.store(SCHED_WORKERS_LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

fn sched_worker_enter() {
    let live = SCHED_WORKERS_LIVE.fetch_add(1, Ordering::Relaxed) + 1;
    SCHED_WORKERS_PEAK.fetch_max(live, Ordering::Relaxed);
}

fn sched_worker_exit() {
    SCHED_WORKERS_LIVE.fetch_sub(1, Ordering::Relaxed);
}

// Task lifecycle: WAITING (suspended, waker registered somewhere) →
// QUEUED (on the ready deque) → POLLING (a worker is inside `poll`) →
// back to WAITING, or REPOLL (a wake landed mid-poll: requeue instead
// of suspending), or DONE.
const T_WAITING: u8 = 0;
const T_QUEUED: u8 = 1;
const T_POLLING: u8 = 2;
const T_REPOLL: u8 = 3;
const T_DONE: u8 = 4;

/// The `'static` half of a driver run: ready queue, per-task states and
/// completion count.  Wakers hold an `Arc` of this (a `Waker` must be
/// `'static`); the non-`'static` futures stay on the driver's stack.
struct SchedCore {
    ready: Mutex<VecDeque<usize>>,
    work: Condvar,
    states: Vec<AtomicU8>,
    done: AtomicUsize,
    total: usize,
}

impl SchedCore {
    fn enqueue(&self, idx: usize) {
        self.ready.lock().unwrap().push_back(idx);
        self.work.notify_one();
    }

    /// Mark one task finished; the last one wakes every parked worker
    /// so they can observe completion and exit.
    fn finish_one(&self) {
        if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            let _guard = self.ready.lock().unwrap();
            self.work.notify_all();
        }
    }

    /// Next runnable task, or `None` once every task is done.  Parks on
    /// the condvar while the deque is empty (tasks are suspended in
    /// modeled collectives) — a cooperative run burns no CPU waiting.
    fn next_ready(&self) -> Option<usize> {
        let mut q = self.ready.lock().unwrap();
        loop {
            if let Some(i) = q.pop_front() {
                return Some(i);
            }
            if self.done.load(Ordering::Acquire) == self.total {
                return None;
            }
            q = self.work.wait(q).unwrap();
        }
    }
}

struct TaskWaker {
    core: Arc<SchedCore>,
    idx: usize,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        let st = &self.core.states[self.idx];
        loop {
            match st.load(Ordering::Acquire) {
                T_WAITING => {
                    if st
                        .compare_exchange(T_WAITING, T_QUEUED, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        self.core.enqueue(self.idx);
                        return;
                    }
                }
                T_POLLING => {
                    if st
                        .compare_exchange(T_POLLING, T_REPOLL, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        return;
                    }
                }
                // QUEUED / REPOLL / DONE: the wake is already recorded
                _ => return,
            }
        }
    }
}

/// Run `tasks` to completion on at most `workers` threads (the calling
/// thread is one of them; `workers` is clamped to the task count).
/// Results come back in task order.  A panicking task is contained: its
/// payload is returned as that slot's `Err`, `on_panic(idx)` runs at
/// panic time so the caller can unblock the panicked task's peers (the
/// rank runtime broadcasts a down notice), and every other task still
/// runs to completion — the exact semantics thread-per-rank execution
/// got from `catch_unwind` + `Comm::abort`.
pub fn drive_tasks<'a, T: Send>(
    workers: usize,
    tasks: Vec<BoxFuture<'a, T>>,
    on_panic: &(dyn Fn(usize) + Sync),
) -> Vec<std::thread::Result<T>> {
    let total = tasks.len();
    if total == 0 {
        return Vec::new();
    }
    let core = Arc::new(SchedCore {
        ready: Mutex::new((0..total).collect()),
        work: Condvar::new(),
        states: (0..total).map(|_| AtomicU8::new(T_QUEUED)).collect(),
        done: AtomicUsize::new(0),
        total,
    });
    let slots: Vec<Mutex<Option<BoxFuture<'a, T>>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<std::thread::Result<T>>>> =
        (0..total).map(|_| Mutex::new(None)).collect();

    let worker = |exclude_caller: bool| {
        if exclude_caller {
            sched_worker_enter();
        }
        while let Some(idx) = core.next_ready() {
            core.states[idx].store(T_POLLING, Ordering::Release);
            let mut fut = slots[idx].lock().unwrap().take().expect("queued task has no future");
            let waker = Waker::from(Arc::new(TaskWaker { core: Arc::clone(&core), idx }));
            let mut cx = Context::from_waker(&waker);
            match catch_unwind(AssertUnwindSafe(|| fut.as_mut().poll(&mut cx))) {
                Ok(Poll::Ready(v)) => {
                    *results[idx].lock().unwrap() = Some(Ok(v));
                    core.states[idx].store(T_DONE, Ordering::Release);
                    core.finish_one();
                }
                Ok(Poll::Pending) => {
                    // restore the future *before* leaving POLLING: while
                    // POLLING, a waker can only set REPOLL, so no other
                    // worker can claim the slot until we requeue it
                    *slots[idx].lock().unwrap() = Some(fut);
                    if core.states[idx]
                        .compare_exchange(T_POLLING, T_WAITING, Ordering::AcqRel, Ordering::Acquire)
                        .is_err()
                    {
                        // a wake landed mid-poll (REPOLL): run it again
                        core.states[idx].store(T_QUEUED, Ordering::Release);
                        core.enqueue(idx);
                    }
                }
                Err(payload) => {
                    drop(fut); // the task's Comm and scratch leases unwind here
                    on_panic(idx);
                    *results[idx].lock().unwrap() = Some(Err(payload));
                    core.states[idx].store(T_DONE, Ordering::Release);
                    core.finish_one();
                }
            }
        }
        if exclude_caller {
            sched_worker_exit();
        }
    };

    let n_workers = workers.max(1).min(total);
    sched_worker_enter();
    std::thread::scope(|scope| {
        for _ in 1..n_workers {
            scope.spawn(|| worker(true));
        }
        worker(false);
    });
    sched_worker_exit();

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("scheduler exited with an unfinished task"))
        .collect()
}

/// Unpark-based waker: drives a single future to completion on the
/// calling OS thread.  This is the compatibility bridge for the legacy
/// thread-per-rank drivers (`run_ranks*`) and the synchronous `Comm`
/// method surface — each blocking call is `block_on(async core)`.
struct ThreadUnparker(std::thread::Thread);

impl Wake for ThreadUnparker {
    fn wake(self: Arc<Self>) {
        self.0.unpark();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.0.unpark();
    }
}

/// Poll `fut` to completion, parking the calling thread between polls.
/// Must not be called from inside a cooperative task (it would pin a
/// scheduler worker); the async rank bodies await their comm cores
/// directly instead.
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let waker = Waker::from(Arc::new(ThreadUnparker(std::thread::current())));
    let mut cx = Context::from_waker(&waker);
    let mut fut = std::pin::pin!(fut);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(v) => return v,
            Poll::Pending => std::thread::park(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for len in [0usize, 1, 7, 64, 1000] {
            for k in [1usize, 2, 3, 8, 17] {
                let rs = chunk_ranges(len, k);
                let mut expect = 0usize;
                for r in &rs {
                    assert_eq!(r.start, expect);
                    expect = r.end;
                }
                assert_eq!(expect, len);
                // balanced: sizes differ by at most one
                let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "len={len} k={k}: {sizes:?}");
            }
        }
    }

    #[test]
    fn map_chunks_matches_serial_for_any_thread_count() {
        let items: Vec<u64> = (0..10_000).collect();
        let serial: u64 = items.iter().map(|x| x * x).sum();
        for threads in [1usize, 2, 3, 8, 0] {
            let parts = map_chunks(threads, &items, |chunk| {
                chunk.iter().map(|x| x * x).sum::<u64>()
            });
            assert_eq!(parts.iter().sum::<u64>(), serial, "threads={threads}");
        }
    }

    #[test]
    fn flat_map_preserves_input_order() {
        let items: Vec<u32> = (0..5_000).collect();
        for threads in [1usize, 2, 8] {
            let out = flat_map_chunks(threads, &items, |chunk| {
                chunk.iter().map(|&x| x * 2).collect::<Vec<u32>>()
            });
            let expect: Vec<u32> = items.iter().map(|&x| x * 2).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        let none: Vec<u32> = vec![];
        let out = map_chunks(8, &none, |c| c.len());
        assert_eq!(out, vec![0]);
        let one = [42u32];
        let out = flat_map_chunks(8, &one, |c| c.to_vec());
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn resolve_auto_is_positive() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }

    #[test]
    fn pooled_map_matches_spawned_for_any_thread_count() {
        let items: Vec<u64> = (0..20_000).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(threads);
            let exec = pool.executor();
            let out = exec.flat_map_chunks(&items, |chunk| {
                chunk.iter().map(|x| x * 3 + 1).collect::<Vec<u64>>()
            });
            assert_eq!(out, serial, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        // the speculative fix loop submits hundreds of small jobs; the
        // pool must not wedge or leak chunks between them
        let pool = WorkerPool::new(4);
        let exec = pool.executor();
        let items: Vec<u32> = (0..4_096).collect();
        for round in 0..200u32 {
            let out = exec.map_chunks(&items, |c| c.iter().map(|&x| x ^ round).sum::<u32>());
            let expect: u32 = items.iter().map(|&x| x ^ round).sum();
            assert_eq!(out.into_iter().sum::<u32>(), expect, "round {round}");
        }
    }

    #[test]
    fn map_range_chunks_is_in_order_and_exact() {
        let pool = WorkerPool::new(8);
        let exec = pool.executor();
        let parts = exec.map_range_chunks(10_000, |r| r.clone());
        let mut expect = 0usize;
        for r in parts {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
        assert_eq!(expect, 10_000);
    }

    #[test]
    fn executor_outliving_pool_still_completes_on_caller() {
        let exec = {
            let pool = WorkerPool::new(4);
            pool.executor()
        }; // pool (and its workers) dropped here
        let items: Vec<u32> = (0..10_000).collect();
        let out = exec.map_chunks(&items, |c| c.len());
        assert_eq!(out.into_iter().sum::<usize>(), 10_000);
    }

    #[test]
    fn serial_executor_never_chunks() {
        let exec = Executor::serial();
        let items: Vec<u32> = (0..100_000).collect();
        let out = exec.map_chunks(&items, |c| c.len());
        assert_eq!(out, vec![100_000]);
    }

    #[test]
    fn nested_submission_runs_inline_instead_of_deadlocking() {
        // submitting to the pool from inside a pool chunk must not wedge
        // (the inner map degrades to the serial path on that thread)
        let pool = WorkerPool::new(4);
        let exec = pool.executor();
        let outer: Vec<u32> = (0..2_048).collect();
        let out = exec.map_chunks(&outer, |chunk| {
            let inner: Vec<u32> = (0..512).collect();
            let nested = exec.map_chunks(&inner, |c| c.len());
            assert_eq!(nested, vec![512], "nested map must run as one inline chunk");
            chunk.len()
        });
        assert_eq!(out.iter().sum::<usize>(), 2_048);
    }

    #[test]
    fn tiny_pooled_inputs_run_inline() {
        let pool = WorkerPool::new(8);
        let exec = pool.executor();
        let out = exec.map_chunks(&[1u32, 2, 3], |c| c.to_vec());
        assert_eq!(out, vec![vec![1, 2, 3]]);
    }

    #[test]
    fn block_on_completes_ready_and_pending_futures() {
        assert_eq!(block_on(async { 6 * 7 }), 42);
        // a future that is Pending once and woken from another thread
        let flag = Arc::new(Mutex::new(false));
        let registered: Arc<Mutex<Option<Waker>>> = Arc::new(Mutex::new(None));
        let (f2, r2) = (Arc::clone(&flag), Arc::clone(&registered));
        let h = std::thread::spawn(move || loop {
            let w = r2.lock().unwrap().take();
            if let Some(w) = w {
                *f2.lock().unwrap() = true;
                w.wake();
                return;
            }
            std::thread::yield_now();
        });
        let out = block_on(std::future::poll_fn(|cx| {
            if *flag.lock().unwrap() {
                Poll::Ready(7u32)
            } else {
                *registered.lock().unwrap() = Some(cx.waker().clone());
                Poll::Pending
            }
        }));
        assert_eq!(out, 7);
        h.join().unwrap();
    }

    #[test]
    fn drive_tasks_runs_many_more_tasks_than_workers() {
        // a cooperative all-to-one: each task yields once, then returns
        let n = 257usize;
        let woken: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(0)).collect();
        let tasks: Vec<BoxFuture<'_, usize>> = (0..n)
            .map(|i| {
                let woken = &woken;
                Box::pin(async move {
                    std::future::poll_fn(|cx| {
                        if woken[i].swap(1, Ordering::AcqRel) == 0 {
                            // first poll: self-wake and yield, exercising
                            // the REPOLL/requeue path
                            cx.waker().wake_by_ref();
                            Poll::Pending
                        } else {
                            Poll::Ready(())
                        }
                    })
                    .await;
                    i * 2
                }) as BoxFuture<'_, usize>
            })
            .collect();
        let out = drive_tasks(3, tasks, &|_| {});
        let vals: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..n).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drive_tasks_contains_panics_and_finishes_survivors() {
        let hits = AtomicUsize::new(0);
        let tasks: Vec<BoxFuture<'_, u32>> = (0..8u32)
            .map(|i| {
                Box::pin(async move {
                    if i == 3 {
                        panic!("task {i} exploded");
                    }
                    i + 100
                }) as BoxFuture<'_, u32>
            })
            .collect();
        let out = drive_tasks(2, tasks, &|idx| {
            assert_eq!(idx, 3);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        for (i, r) in out.into_iter().enumerate() {
            if i == 3 {
                assert!(r.is_err());
            } else {
                assert_eq!(r.unwrap(), i as u32 + 100);
            }
        }
    }

    #[test]
    fn drive_tasks_worker_peak_tracks_budget_not_task_count() {
        reset_sched_worker_peak();
        let before = sched_worker_peak();
        let tasks: Vec<BoxFuture<'_, ()>> =
            (0..512).map(|_| Box::pin(async {}) as BoxFuture<'_, ()>).collect();
        let out = drive_tasks(4, tasks, &|_| {});
        assert_eq!(out.len(), 512);
        // racy upper bound when other tests drive schedulers in
        // parallel, so only assert the budget-shaped lower/upper frame
        // relative to this driver: it added at most 4 workers
        assert!(sched_worker_peak() >= 1);
        assert!(sched_worker_peak() <= before + 4 + 64, "peak unexpectedly exploded");
    }
}
