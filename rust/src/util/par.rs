//! Shared-memory parallel execution layer for the on-node kernels.
//!
//! The paper's on-node coloring is Deveci et al.'s bit-based kernels
//! running data-parallel over the worklist; this module is the Rust twin
//! of that execution model: a scoped-thread chunked map with no external
//! dependencies (`std::thread::scope` is already the idiom of the rank
//! runtime in `distributed/comm.rs`).
//!
//! Determinism contract: [`map_chunks`] splits the input into contiguous
//! in-order chunks and returns the per-chunk results **in chunk order**,
//! so any algorithm whose chunk function is a pure map over a snapshot
//! (the Jacobi formulation of VB_BIT/EB_BIT/NB_BIT) produces output that
//! is bit-identical for every thread count — asserted by
//! `rust/tests/parallel_kernels.rs`.

use std::cell::Cell;
use std::ops::Range;

use crate::util::timer::thread_cpu_now;

/// Below this many items per worker, fan-out costs more than it saves
/// (thread spawn is ~10µs; a worklist item is ~100ns): run serially.
/// Chunk boundaries never affect results, so this is safe to tune.
const MIN_ITEMS_PER_THREAD: usize = 512;

thread_local! {
    /// CPU nanoseconds burned by this thread's *workers* in `map_chunks`
    /// fan-outs (monotone counter).  `SplitTimer::comp` measures the
    /// calling thread's CPU clock, which cannot see worker threads;
    /// crediting worker CPU here keeps per-rank comp accounting honest
    /// when the kernels run with threads > 1.
    static WORKER_CPU_NS: Cell<u64> = const { Cell::new(0) };
}

/// Monotone per-thread counter of worker CPU time (ns) spent on this
/// thread's behalf.  Read before/after a computation and add the delta
/// to the calling thread's own CPU clock for total attributed comp.
pub fn worker_cpu_ns() -> u64 {
    WORKER_CPU_NS.with(|c| c.get())
}

fn credit_worker_cpu(ns: u64) {
    WORKER_CPU_NS.with(|c| c.set(c.get() + ns));
}

/// Resolve a thread-count knob: `0` means one worker per available core.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Workers actually worth launching for `len` items.
fn effective_threads(threads: usize, len: usize) -> usize {
    resolve_threads(threads).min(len / MIN_ITEMS_PER_THREAD).max(1)
}

/// Split `0..len` into `k` contiguous, balanced, in-order ranges.
pub fn chunk_ranges(len: usize, k: usize) -> Vec<Range<usize>> {
    let k = k.max(1).min(len.max(1));
    let base = len / k;
    let rem = len % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let sz = base + usize::from(i < rem);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// Apply `f` to contiguous chunks of `items` on up to `threads` scoped
/// workers; results are returned in chunk (= input) order.  `threads`
/// of 0 means auto; 1 (or a small input) degenerates to a single
/// in-thread call with no spawning.
pub fn map_chunks<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&[T]) -> R + Sync,
) -> Vec<R> {
    let k = effective_threads(threads, items.len());
    if k <= 1 {
        return vec![f(items)];
    }
    let ranges = chunk_ranges(items.len(), k);
    let f = &f;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len() - 1);
        for r in &ranges[1..] {
            let chunk = &items[r.clone()];
            // each worker reports its own CPU time so the caller can
            // attribute it (the caller's CPU clock cannot see workers)
            handles.push(scope.spawn(move || {
                let t0 = thread_cpu_now();
                let out = f(chunk);
                (out, thread_cpu_now().saturating_sub(t0))
            }));
        }
        // chunk 0 runs on the calling thread while the workers spin
        let mut out = Vec::with_capacity(ranges.len());
        out.push(f(&items[ranges[0].clone()]));
        let mut foreign_ns = 0u64;
        for h in handles {
            let (r, cpu) = h.join().expect("parallel worker panicked");
            foreign_ns += cpu.as_nanos() as u64;
            out.push(r);
        }
        credit_worker_cpu(foreign_ns);
        out
    })
}

/// [`map_chunks`] flattened: concatenate the per-chunk `Vec`s in chunk
/// order.  The common shape of the kernels' staged-write passes.
pub fn flat_map_chunks<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&[T]) -> Vec<R> + Sync,
) -> Vec<R> {
    let parts = map_chunks(threads, items, f);
    match <[_; 1]>::try_from(parts) {
        Ok([only]) => only, // serial path: no re-copy
        Err(parts) => {
            let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
            for mut p in parts {
                out.append(&mut p);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for len in [0usize, 1, 7, 64, 1000] {
            for k in [1usize, 2, 3, 8, 17] {
                let rs = chunk_ranges(len, k);
                let mut expect = 0usize;
                for r in &rs {
                    assert_eq!(r.start, expect);
                    expect = r.end;
                }
                assert_eq!(expect, len);
                // balanced: sizes differ by at most one
                let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "len={len} k={k}: {sizes:?}");
            }
        }
    }

    #[test]
    fn map_chunks_matches_serial_for_any_thread_count() {
        let items: Vec<u64> = (0..10_000).collect();
        let serial: u64 = items.iter().map(|x| x * x).sum();
        for threads in [1usize, 2, 3, 8, 0] {
            let parts = map_chunks(threads, &items, |chunk| {
                chunk.iter().map(|x| x * x).sum::<u64>()
            });
            assert_eq!(parts.iter().sum::<u64>(), serial, "threads={threads}");
        }
    }

    #[test]
    fn flat_map_preserves_input_order() {
        let items: Vec<u32> = (0..5_000).collect();
        for threads in [1usize, 2, 8] {
            let out = flat_map_chunks(threads, &items, |chunk| {
                chunk.iter().map(|&x| x * 2).collect::<Vec<u32>>()
            });
            let expect: Vec<u32> = items.iter().map(|&x| x * 2).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        let none: Vec<u32> = vec![];
        let out = map_chunks(8, &none, |c| c.len());
        assert_eq!(out, vec![0]);
        let one = [42u32];
        let out = flat_map_chunks(8, &one, |c| c.to_vec());
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn resolve_auto_is_positive() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }
}
