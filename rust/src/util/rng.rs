//! Seeded xoshiro256** PRNG — the repo is fully offline, so we carry our
//! own small generator instead of the `rand` crate.  Deterministic across
//! platforms; every generator, partitioner and test takes an explicit seed.

use super::splitmix64;

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // seed the state via splitmix64, as recommended by the authors
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *v = splitmix64(x);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_stays_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut w = v.clone();
        w.sort_unstable();
        assert_eq!(w, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
