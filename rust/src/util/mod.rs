//! Small shared utilities: deterministic hashing, PRNG, bitsets, timers,
//! and the scoped-thread parallel execution layer.

pub mod bitset;
pub mod par;
pub mod rng;
pub mod timer;

/// splitmix64: deterministic 64-bit mixer.
///
/// This is the `rand(GID)` of Algorithm 4 (Bozdağ et al.'s random
/// tie-breaking): both ranks involved in a distributed conflict evaluate
/// `splitmix64(seed ^ GID)` independently and agree on the loser without
/// any communication.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// The per-GID random priority used by conflict resolution.
#[inline]
pub fn gid_rand(seed: u64, gid: u64) -> u64 {
    splitmix64(seed ^ splitmix64(gid))
}

/// 32-bit mixer (lowbias32): the *local* tie-breaking priority shared
/// bit-for-bit with the Pallas kernels (`python/compile/kernels/vb_bit.py`).
///
/// The speculative kernels uncolor the conflict endpoint with the larger
/// `(mix32(i), i)` pair.  A raw-index rule would serialize lattice-ordered
/// graphs into O(diameter) rounds (every vertex waits for its lower-index
/// neighbor); hashed priorities give O(log n) expected rounds — the §Perf
/// fix that took VB_BIT on a 32³ mesh from 19 ms to ~1 ms.
#[inline]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7feb_352d);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846c_a68b);
    x ^ (x >> 16)
}

/// Does local vertex `a` beat (keep its color against) local vertex `b`?
#[inline]
pub fn local_priority_wins(a: u32, b: u32) -> bool {
    (mix32(a), a) < (mix32(b), b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        assert_eq!(splitmix64(42), splitmix64(42));
        assert_ne!(splitmix64(42), splitmix64(43));
    }

    #[test]
    fn splitmix_spreads_low_bits() {
        // sequential inputs should not produce sequential outputs
        let a = splitmix64(1);
        let b = splitmix64(2);
        assert!(a.abs_diff(b) > 1 << 32);
    }

    #[test]
    fn gid_rand_depends_on_seed_and_gid() {
        assert_ne!(gid_rand(1, 7), gid_rand(2, 7));
        assert_ne!(gid_rand(1, 7), gid_rand(1, 8));
        assert_eq!(gid_rand(5, 9), gid_rand(5, 9));
    }
}
